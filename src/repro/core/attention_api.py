"""Unified attention entry point: one call, pluggable backends.

HASTILY's O(l) streaming softmax/attention (§III-B2, §IV) exists in this
repo in several concrete forms — the pure-jnp online-softmax scan, the Pallas
TPU kernel, the inter-chip ring, and the materialised-logits reference.
Related CIM designs (X-Former, CIMple) treat softmax/attention the same way:
a swappable compute backend behind one dataflow interface.  This module is
that seam.

Usage::

    from repro.core.attention_api import attention

    out = attention(q, k, v, causal=True, backend="pallas")   # explicit
    out = attention(q, k, v, causal=True)                     # auto-resolve

Backends are registered with :func:`register_backend`; each carries a
``supports`` predicate so ``backend="auto"`` can pick the fastest
implementation whose constraints hold for the actual call (device platform,
static vs traced lengths, ring-buffer position tables, query length).
Registering a new variant is one decorator — models pick it up via
``cfg.attn_backend`` with no model-code changes.

All backends share one signature: ``fn(q, k, v, **AttentionCall kwargs)``
with q ``(B, Hq, Lq, D)``, k/v ``(B, Hkv, Lkv, D)``, ``Hq % Hkv == 0`` (GQA),
returning ``(B, Hq, Lq, D)`` in q's dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.streaming_attention import naive_attention, streaming_attention

AttentionFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class AttentionCall:
    """Static facts about one attention call that drive backend resolution."""
    lq: int
    lkv: int
    platform: str
    static_lengths: bool          # q_offset / kv_len are python ints (or None)
    has_kv_pos: bool              # ring-buffer position table supplied
    inside_shard_map: bool        # an axis_name was supplied
    has_page_table: bool = False  # k/v are page pools + a (B, P) page table
    is_ragged: bool = False       # packed (1, Hq, T, D) stream + q_pos (T,)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: AttentionFn
    supports: Callable[[AttentionCall], bool]
    auto_ok: Callable[[AttentionCall], bool]   # gate for backend="auto"
    doc: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}

#: resolution order for ``backend="auto"`` — first auto-eligible backend wins.
#: "paged_varlen"/"paged" are the only backends that read page pools (varlen
#: for packed ragged streams, paged for (lanes, C) blocks), "ring" is only
#: eligible inside shard_map, "naive" is the last resort.
_AUTO_ORDER: Tuple[str, ...] = ("paged_varlen", "paged", "pallas",
                                "naive_decode", "jnp", "ring", "naive")


def register_backend(name: str, *, supports: Callable[[AttentionCall], bool],
                     auto_ok: Optional[Callable[[AttentionCall], bool]] = None,
                     doc: str = "") -> Callable[[AttentionFn], AttentionFn]:
    """Decorator: register ``fn`` as attention backend ``name``.

    ``supports(call)`` must be a cheap, trace-free predicate; it validates
    explicit selection.  ``auto_ok`` (default: same as ``supports``)
    additionally gates ``backend="auto"`` — e.g. the Pallas kernel *can* run
    anywhere via interpret mode but should only be auto-picked on TPU.
    """
    def deco(fn: AttentionFn) -> AttentionFn:
        _REGISTRY[name] = BackendSpec(name=name, fn=fn, supports=supports,
                                      auto_ok=auto_ok or supports,
                                      doc=doc or (fn.__doc__ or ""))
        return fn
    return deco


def get_backend(name: str) -> BackendSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def backend_for_config(attn_backend: str, attn_impl: str = "streaming") -> str:
    """Map config fields to a registry name.

    ``attn_backend`` (a registry name) wins when set; at its ``"auto"``
    default the legacy ``attn_impl`` field ("streaming" | "naive" | "pallas")
    is honoured.  "naive"/"pallas" keep their exact pre-registry behaviour;
    "streaming" (the old default) maps to auto, which is identical off-TPU
    and *upgrades* prefill to the Pallas kernel on TPU — that platform
    dispatch is the point of the registry.  Pin ``attn_backend="jnp"`` for
    the bit-exact streaming scan everywhere (e.g. streaming-vs-pallas A/Bs).
    """
    if attn_backend and attn_backend != "auto":
        return attn_backend
    legacy = {"streaming": "auto", "naive": "naive", "pallas": "pallas"}
    if attn_impl not in legacy:
        raise KeyError(f"unknown attn_impl {attn_impl!r}; "
                       f"known: {sorted(legacy)}")
    return legacy[attn_impl]


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------

def _is_static(x) -> bool:
    return x is None or isinstance(x, (int, float))


def describe_call(q, k, *, q_offset=0, kv_len=None, kv_pos=None,
                  page_table=None, q_pos=None,
                  axis_name: Optional[str] = None,
                  platform: Optional[str] = None) -> AttentionCall:
    return AttentionCall(
        lq=q.shape[2], lkv=k.shape[2],
        platform=platform or jax.default_backend(),
        static_lengths=_is_static(q_offset) and _is_static(kv_len),
        has_kv_pos=kv_pos is not None,
        inside_shard_map=axis_name is not None,
        has_page_table=page_table is not None,
        is_ragged=q_pos is not None)


def resolve_backend(backend: str, call: AttentionCall, *,
                    fallback: bool = False) -> BackendSpec:
    """Explicit name → validate; ``"auto"`` → first eligible in _AUTO_ORDER.

    ``fallback=True`` downgrades an unsupported *explicit* choice to auto
    resolution instead of raising — the config-driven model path uses this so
    e.g. ``attn_backend="pallas"`` still decodes (the kernel has no cached
    path) while direct API callers get a hard error.
    """
    if backend != "auto":
        spec = get_backend(backend)
        if spec.supports(call):
            return spec
        if not fallback:
            raise ValueError(
                f"attention backend {backend!r} does not support this call: "
                f"{call}")
    for name in _AUTO_ORDER:
        spec = _REGISTRY.get(name)
        if spec is not None and spec.auto_ok(call):
            return spec
    raise ValueError(f"no registered attention backend supports this call: "
                     f"{call}")


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              backend: str = "auto",
              scale: Optional[float] = None,
              causal: bool = False,
              window: Optional[int] = None,
              cap: Optional[float] = None,
              block_k: int = 512,
              exp_mode: str = "lut",
              q_offset: jax.Array | int = 0,
              kv_len: Optional[jax.Array | int] = None,
              kv_pos: Optional[jax.Array] = None,
              page_table: Optional[jax.Array] = None,
              q_pos: Optional[jax.Array] = None,
              cu_seqlens: Optional[jax.Array] = None,
              kernel_config: Optional[Any] = None,
              axis_name: Optional[str] = None,
              fallback: bool = False) -> jax.Array:
    """The single attention entry point (see module docstring).

    ``backend="auto"`` resolves per-call: the Pallas kernel where its static
    constraints hold on TPU, the O(L)-logits naive row for single-token
    decode, the streaming jnp scan otherwise.  Pass a registered name to pin
    an implementation (tests pin ``"naive"`` as the oracle); an unsupported
    explicit choice raises unless ``fallback=True`` (the model path).

    ``page_table`` switches the calling convention to *paged*: k/v are page
    pools ``(num_pages, Hkv, page_size, D)``, ``page_table`` is the (B, P)
    physical page per table slot and ``kv_len`` the (B,) live rows per lane
    (query rows included — ``Lq > 1`` is a chunked-prefill block at
    positions ``kv_len - Lq + i`` with the causal intra-chunk mask implied).
    Only backends whose ``supports`` accepts pool+page-table callers (the
    "paged" kernel) resolve; contiguous backends never see the kwarg.

    ``q_pos`` switches the paged convention to *ragged*: q is one packed
    token stream ``(1, Hq, T, D)`` (lane segments abutting, no per-lane
    padding), ``page_table`` holds *per-token* rows ``(T, P)`` and
    ``q_pos`` (T,) is each token's absolute position — its causal bound.
    Only the "paged_varlen" backend resolves ragged calls.  ``cu_seqlens``
    (S+1,) lane boundaries enable its q-block-tiled dataflow, whose block
    shapes come from ``kernel_config`` (a ``kernels.autotune.KernelConfig``;
    ``None`` consults the autotuner's active/persisted table — this is the
    backend-resolution seam the roofline sweep feeds).
    """
    call = describe_call(q, k, q_offset=q_offset, kv_len=kv_len, kv_pos=kv_pos,
                         page_table=page_table, q_pos=q_pos,
                         axis_name=axis_name)
    spec = resolve_backend(backend, call, fallback=fallback)
    kw: Dict[str, Any] = dict(scale=scale, causal=causal, window=window,
                              cap=cap, block_k=block_k, exp_mode=exp_mode,
                              q_offset=q_offset, kv_len=kv_len, kv_pos=kv_pos)
    if page_table is not None:
        kw["page_table"] = page_table
    if q_pos is not None:
        kw["q_pos"] = q_pos
        kw["cu_seqlens"] = cu_seqlens
        kw["kernel_config"] = kernel_config
    if axis_name is not None:
        kw["axis_name"] = axis_name
    return spec.fn(q, k, v, **kw)


# --------------------------------------------------------------------------
# built-in backends
# --------------------------------------------------------------------------

@register_backend(
    "naive",
    supports=lambda call: not call.inside_shard_map
    and not call.has_page_table,
    doc="Materialised-logits reference (PUMA dataflow): O(l²) memory; the "
        "correctness oracle every other backend is tested against.")
def _naive(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
           q_offset, kv_len, kv_pos):
    del block_k  # logits are materialised in one piece
    return naive_attention(q, k, v, scale=scale, causal=causal, window=window,
                           cap=cap, exp_mode=exp_mode, q_offset=q_offset,
                           kv_len=kv_len, kv_pos=kv_pos)


@register_backend(
    "naive_decode",
    supports=lambda call: call.lq == 1 and not call.inside_shard_map
    and not call.has_page_table,
    doc="Single-token decode fast path: the logits row is O(L) already — the "
        "KV-block scan buys nothing and costs a collective-permute per block "
        "on a sharded cache (measured 12 GiB/token at 500k ctx; §Perf).")
def _naive_decode(q, k, v, **kw):
    return _naive(q, k, v, **kw)


@register_backend(
    "jnp",
    supports=lambda call: not call.inside_shard_map
    and not call.has_page_table,
    doc="Pure-jnp streaming scan (HASTILY §IV): online-softmax over KV "
        "blocks, O(l) memory, flash-style custom VJP, fully dynamic "
        "lengths/positions.  The default on CPU and for cached decode.")
def _jnp(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
         q_offset, kv_len, kv_pos):
    return streaming_attention(q, k, v, scale=scale, causal=causal,
                               window=window, cap=cap, block_k=block_k,
                               exp_mode=exp_mode, q_offset=q_offset,
                               kv_len=kv_len, kv_pos=kv_pos)


def _pallas_supported(call: AttentionCall) -> bool:
    # The kernel wants static lengths (serving buckets them), no ring-buffer
    # position tables, and multi-row queries (decode rows go to naive_decode).
    return (call.static_lengths and not call.has_kv_pos
            and not call.inside_shard_map and not call.has_page_table
            and call.lq > 1)


@register_backend(
    "pallas",
    supports=_pallas_supported,
    # interpret=True keeps it runnable off-TPU when explicitly selected, but
    # auto resolution only picks the kernel on real TPU hardware.
    auto_ok=lambda call: _pallas_supported(call) and call.platform == "tpu",
    doc="Pallas TPU kernel forward (interpret mode off-TPU) with the jnp "
        "flash backward attached as custom VJP — kernel on the hot forward "
        "path, autodiff still works for training.  Static lengths only.")
def _pallas(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
            q_offset, kv_len, kv_pos):
    assert kv_pos is None, "pallas backend has no ring-buffer support"
    from repro.kernels import streaming_attention as pallas_attention
    if scale is None:
        scale = q.shape[-1] ** -0.5
    kernel_kw = dict(scale=float(scale), causal=causal, window=window,
                     cap=cap, exp_mode=exp_mode,
                     block_q=min(block_k, 512), block_k=min(block_k, 512),
                     q_offset=int(q_offset),
                     kv_len=None if kv_len is None else int(kv_len))
    jnp_kw = dict(scale=scale, causal=causal, window=window, cap=cap,
                  block_k=block_k, exp_mode=exp_mode, q_offset=q_offset,
                  kv_len=kv_len)

    @jax.custom_vjp
    def attn(q, k, v):
        return pallas_attention(q, k, v, **kernel_kw)

    def attn_fwd(q, k, v):
        return attn(q, k, v), (q, k, v)

    def attn_bwd(res, g):
        qr, kr, vr = res
        _, vjp = jax.vjp(
            lambda a, b, c: streaming_attention(a, b, c, **jnp_kw),
            qr, kr, vr)
        return vjp(g)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn(q, k, v)


@register_backend(
    "paged",
    supports=lambda call: call.has_page_table and not call.is_ragged
    and not call.inside_shard_map and not call.has_kv_pos,
    doc="Paged attention: reads KV pages in place from the pool through the "
        "(B, P) page table — the Pallas kernel on TPU (scalar-prefetch "
        "page-indexed DMA), the jnp page-block scan elsewhere.  Lq == 1 is "
        "decode; Lq > 1 is a chunked-prefill block (causal intra-chunk mask "
        "implied).  No gathered contiguous cache view is materialised.")
def _paged(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
           q_offset, kv_len, kv_pos, page_table):
    assert kv_pos is None, "paged backend has no ring-buffer support"
    assert kv_len is not None, "paged calls must pass per-lane kv_len"
    assert causal or q.shape[2] == 1, \
        "paged chunks are causal by construction — bidirectional multi-row " \
        "paged attention is not supported"
    # Causality is structural: query row i sits at position kv_len - Lq + i,
    # so the per-row bound `col <= kv_len - Lq + i` is both the length mask
    # and the causal intra-chunk mask (decode: the plain kv_len mask).
    # block_k is a streaming-scan tile size — page blocks are sized by
    # page_size alone.
    del causal, q_offset, block_k
    from repro.kernels.paged_attention import paged_attention
    return paged_attention(q, k, v, page_table, kv_len, scale=scale, cap=cap,
                           window=window, exp_mode=exp_mode)


@register_backend(
    "paged_varlen",
    supports=lambda call: call.has_page_table and call.is_ragged
    and not call.has_kv_pos,
    doc="Ragged (varlen) paged attention: q is one packed (1, Hq, T, D) "
        "token stream with per-token page-table rows (T, P) and per-token "
        "causal bounds q_pos (T,) — the token-level serving step, no "
        "(lanes, C) padding.  cu_seqlens lane boundaries switch on the "
        "q-block-tiled dataflow (each KV page read once per block, not "
        "once per token); block shapes come from the autotuner's "
        "KernelConfig (kernels/paged_attention/varlen.py).  Inside "
        "shard_map (axis_name set) q/k/v carry this device's head band "
        "against its local pool shard; the full head axis is rebuilt with "
        "one tiled all-gather — HASTILY's reduce-and-gather with the "
        "online-softmax reduce kept per-head-local (docs/architecture.md).")
def _paged_varlen(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
                  q_offset, kv_len, kv_pos, page_table, q_pos,
                  cu_seqlens=None, kernel_config=None, axis_name=None):
    assert kv_pos is None, "ragged backend has no ring-buffer support"
    assert causal, "ragged paged streams are causal by construction"
    assert q.shape[0] == 1, \
        f"ragged q is one packed (1, Hq, T, D) stream, got batch {q.shape[0]}"
    # Positions live entirely in q_pos; kv_len/q_offset are the padded
    # convention's fields and block_k a streaming-scan tile size.
    del causal, q_offset, kv_len, block_k
    from repro.kernels.autotune import active_config
    from repro.kernels.paged_attention import paged_attention_varlen
    cfg = kernel_config if kernel_config is not None else active_config()
    qt = jnp.moveaxis(q[0], 1, 0)                       # (T, Hq, D)
    out = paged_attention_varlen(qt, k, v, page_table, q_pos, scale=scale,
                                 cap=cap, window=window, exp_mode=exp_mode,
                                 cu_seqlens=cu_seqlens,
                                 block_q=cfg.block_q,
                                 block_pages=cfg.block_pages,
                                 dequant=cfg.dequant)
    out = jnp.moveaxis(out, 0, 1)[None]                 # (1, Hq, T, D)
    if axis_name is not None:
        # Head bands concatenate in mesh order — pure data movement, no
        # cross-device float arithmetic, so per-head outputs are bitwise
        # what a single device would compute.
        out = jax.lax.all_gather(out, axis_name, axis=1, tiled=True)
    return out


@register_backend(
    "ring",
    supports=lambda call: call.inside_shard_map
    and not call.has_page_table,
    doc="Inter-chip ring attention: KV shards rotate around a mesh axis via "
        "ppermute while resident Q streams them (HASTILY §IV lifted to ICI). "
        "Only callable inside shard_map — pass axis_name.")
def _ring(q, k, v, *, scale, causal, window, cap, block_k, exp_mode,
          q_offset, kv_len, kv_pos, axis_name):
    del block_k  # the ring hop is the block
    if (kv_pos is not None or kv_len is not None
            or not _is_static(q_offset) or q_offset != 0):
        raise ValueError("ring backend: positions derive from the mesh; "
                         "kv_pos/kv_len/q_offset are not supported")
    from repro.core.ring_attention import ring_attention
    return ring_attention(q, k, v, axis_name, scale=scale, causal=causal,
                          window=window, cap=cap, exp_mode=exp_mode)
