"""Numerically-stable softmax built on the HASTILY LUT exponential (paper §III-B).

Implements the paper's five-step softmax (maxima → subtract → exponent → reduce →
divide) with the exponent supplied by ``lut_exp``.  Supports the attention-side
extras the assigned architectures need: masking (additive or boolean), gemma-style
logit soft-capping, and a pluggable exp so the "PUMA baseline" (plain
``jnp.exp``) and the HASTILY path share one code path for A/B comparisons.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp

ExpFn = Callable[[jax.Array], jax.Array]

NEG_INF = -1e30  # finite mask value: keeps (x - max) well-defined everywhere


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def lut_softmax(x: jax.Array, axis: int = -1, *,
                where: Optional[jax.Array] = None,
                exp_fn: ExpFn = lut_exp,
                cap: Optional[float] = None) -> jax.Array:
    """softmax(x) with LUT exponent.  ``where`` False positions get probability 0."""
    x = softcap(x, cap)
    if where is not None:
        x = jnp.where(where, x, NEG_INF)
    m = jnp.max(x, axis=axis, keepdims=True)
    # Fully-masked rows: max == NEG_INF → shift to 0 to avoid inf - inf.
    m = jnp.where(m <= NEG_INF, 0.0, m)
    e = exp_fn(x - m)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return e / jnp.maximum(s, 1e-30)


def lut_log_softmax(x: jax.Array, axis: int = -1, *,
                    where: Optional[jax.Array] = None,
                    exp_fn: ExpFn = lut_exp) -> jax.Array:
    """log-softmax via the LUT sum (paper §VII mentions log-softmax extension).

    ``where`` False positions score ``NEG_INF`` — the in-step sampler's
    Gumbel-max draw (``serving/sampling.py``) runs over these scores, so
    top-k/top-p-masked tokens can never win the argmax."""
    if where is not None:
        x = jnp.where(where, x, NEG_INF)
    m = jnp.max(x, axis=axis, keepdims=True)
    m = jnp.where(m <= NEG_INF, 0.0, m)
    e = exp_fn(x - m)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    s = jnp.maximum(jnp.sum(e, axis=axis, keepdims=True), 1e-30)
    return x - m - jnp.log(s)
