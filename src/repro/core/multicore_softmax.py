"""Multi-core softmax — HASTILY §III-B2 mapped onto the TPU mesh.

The paper parallelises the softmax of one long row across CIM *cores*: each core
computes a local maximum and a partial exp-sum, then the partials are gathered in a
**binary tree** (O(log n) depth) through shared memory.  On a TPU pod the cores are
chips and the shared memory is the ICI: ``jax.lax.pmax / psum`` over a mesh axis are
tree/ring all-reduces with exactly that O(log n) combine depth.

Two implementations are provided:

* ``sharded_softmax`` — the production path: local max/exp/sum + ``pmax``/``psum``.
* ``tree_allreduce`` — a literal recursive-doubling butterfly built from
  ``ppermute`` rounds, mirroring the paper's Fig. 5 gather; used in tests to show
  it is step-for-step equivalent to the collective (and to count the log₂(n)
  rounds explicitly).

Both must be called inside ``shard_map`` with the reduced axis sharded.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp
from repro.parallel.compat import axis_size


def tree_allreduce(x: jax.Array, op: Callable, axis_name: str) -> jax.Array:
    """Recursive-doubling all-reduce via ppermute — the paper's binary-tree gather.

    O(log₂ n) rounds; after round i every device holds the reduction over its
    2^(i+1)-device group.  Requires the axis size to be a power of two.
    """
    n = axis_size(axis_name)
    assert n & (n - 1) == 0, f"tree_allreduce needs power-of-two axis, got {n}"
    dist = 1
    while dist < n:
        perm = [(i, i ^ dist) for i in range(n)]  # butterfly partner exchange
        other = jax.lax.ppermute(x, axis_name, perm)
        x = op(x, other)
        dist *= 2
    return x


def sharded_softmax(x_local: jax.Array, axis_name: str, *,
                    exp_fn=lut_exp, axis: int = -1) -> jax.Array:
    """Softmax over a dimension sharded across ``axis_name``.

    Each shard: local max → subtract → LUT-exp → local sum; the global max and
    denominator are combined with tree all-reduces (paper Fig. 5 right).
    """
    m_local = jnp.max(x_local, axis=axis, keepdims=True)
    m = jax.lax.pmax(m_local, axis_name)
    e = exp_fn(x_local - m)
    s_local = jnp.sum(e, axis=axis, keepdims=True)
    s = jax.lax.psum(s_local, axis_name)
    return e / jnp.maximum(s, 1e-30)


def sharded_softmax_tree(x_local: jax.Array, axis_name: str, *,
                         exp_fn=lut_exp, axis: int = -1) -> jax.Array:
    """Same as ``sharded_softmax`` but with the explicit ppermute butterfly."""
    m_local = jnp.max(x_local, axis=axis, keepdims=True)
    m = tree_allreduce(m_local, jnp.maximum, axis_name)
    e = exp_fn(x_local - m)
    s_local = jnp.sum(e, axis=axis, keepdims=True)
    s = tree_allreduce(s_local, jnp.add, axis_name)
    return e / jnp.maximum(s, 1e-30)
