"""INT8 quantisation substrate (paper §V: all HASTILY evaluations are INT8).

The CIM crossbar computes with 8-bit weights/inputs; the TPU analogue is the MXU's
native int8×int8→int32 path (~2× bf16 throughput on v5e).  We implement symmetric
quantisation:

* weights — per-output-channel scales (absmax), static;
* activations — per-tensor dynamic absmax (computed at runtime, like the DAC input
  range in the paper's crossbar).

``QTensor`` is a pytree so quantised params flow through jit/pjit/shard_map and the
checkpointing layer unchanged.  The Pallas kernel lives in
``repro.kernels.int8_matmul``; ``int8_matmul`` below is the pure-jnp path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    """int8 values + float scale. ``scale`` broadcasts against ``values``."""
    values: jax.Array   # int8
    scale: jax.Array    # f32

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self) -> jax.Array:
        return self.values.astype(jnp.float32) * self.scale


def quantize(w: jax.Array, axis: int | tuple = -1, *, bits: int = 8) -> QTensor:
    """Symmetric per-channel quantisation.  ``axis``: reduced (input) dims."""
    qmax = 2.0 ** (bits - 1) - 1.0
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QTensor(q, scale)


def quantize_dynamic(x: jax.Array, *, bits: int = 8) -> QTensor:
    """Per-tensor dynamic activation quantisation (paper's input DAC range)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QTensor(q, scale)


def int8_matmul(x: jax.Array, wq: QTensor) -> jax.Array:
    """x (…, K) f32 × wq (K, N) int8 → (…, N) f32.

    Activations are dynamically quantised; the contraction accumulates in int32
    (the MXU-native path the Pallas kernel targets), then both scales are applied.
    """
    xq = quantize_dynamic(x)
    acc = jax.lax.dot_general(
        xq.values, wq.values,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xq.scale * jnp.squeeze(wq.scale, 0)


def dense_maybe_quant(x: jax.Array, w, *, use_int8: bool = False) -> jax.Array:
    """Single dispatch point used by all model code: f32/bf16 or int8 matmul."""
    if isinstance(w, QTensor):
        return int8_matmul(x, w)
    if use_int8:
        return int8_matmul(x, quantize(w, axis=0))
    return jnp.einsum("...k,kn->...n", x, w)
