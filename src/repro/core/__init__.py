"""HASTILY core: the paper's contribution as composable JAX modules.

- ``lut_exp`` / ``lut_softmax``: the UCLM 128-entry LUT exponential (paper III-B1).
- ``streaming_attention``: fine-grained-pipelined attention, O(l) memory (paper IV).
- ``multicore_softmax`` / ``ring_attention``: multi-chip softmax/attention with
  tree gathers (paper III-B2) and KV ring streaming.
- ``quant``: the INT8 substrate (paper V).
"""
from repro.core.lut_exp import lut_exp, lut_exp2, make_table, K
from repro.core.lut_softmax import lut_softmax, lut_log_softmax, softcap
from repro.core.streaming_attention import streaming_attention, naive_attention
from repro.core.attention_api import (attention, backend_for_config,
                                      get_backend, list_backends,
                                      register_backend, resolve_backend)
from repro.core.ring_attention import ring_attention, distributed_decode_attention
from repro.core.multicore_softmax import (sharded_softmax, sharded_softmax_tree,
                                          tree_allreduce)
from repro.core.quant import QTensor, quantize, quantize_dynamic, int8_matmul

__all__ = [
    "lut_exp", "lut_exp2", "make_table", "K",
    "lut_softmax", "lut_log_softmax", "softcap",
    "streaming_attention", "naive_attention",
    "attention", "backend_for_config", "get_backend", "list_backends",
    "register_backend", "resolve_backend",
    "ring_attention", "distributed_decode_attention",
    "sharded_softmax", "sharded_softmax_tree", "tree_allreduce",
    "QTensor", "quantize", "quantize_dynamic", "int8_matmul",
]
