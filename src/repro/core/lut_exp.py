"""LUT-based exponential — the math at the heart of HASTILY's UCLM (paper §III-B1).

The paper computes ``e^x = 2^n · 2^(d/K) · e^r`` (Harrison/Tak/Tang decomposition)
with a K=128-entry lookup table of ``2^(d/K)`` values stored *inside* the SRAM
compute array.  ``n = ⌊x/ln2⌋`` selects a bit-shift, ``d`` indexes the table, and
the residual ``e^r`` (``0 ≤ r < ln2/K``) is approximated as ``1`` (order 0,
error < 0.54%) or ``1 + r`` (order 1, error < 0.0015%).

TPU adaptation: ``2^n`` is an exact exponent-field bit-twiddle, the table lives in
VMEM (one 128-lane VREG row — K=128 is exactly the TPU lane width), and the lookup
is a gather.  The Pallas kernel (``repro.kernels.lut_exp``) performs the gather as a
one-hot × table matmul on the MXU — the same unit that executes the MVMs, which is
the UCLM "unified compute and lookup" property.

This module is the pure-jnp shared math: both the kernel and the reference oracle
import from here, so there is a single source of truth for the decomposition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

K = 128  # table entries; == TPU lane width (paper uses K=128 as well)
LN2 = float(np.log(2.0))
LOG2E = float(1.0 / np.log(2.0))
# Below this input, e^x underflows f32 anyway; used to make exp(-inf) == 0 exact.
UNDERFLOW_X = -87.0


@functools.lru_cache(maxsize=None)
def _table_np(k: int = K) -> np.ndarray:
    return (2.0 ** (np.arange(k, dtype=np.float64) / k)).astype(np.float32)


def make_table(k: int = K, dtype=jnp.float32) -> jax.Array:
    """The 128-entry ``2^(d/K)`` table the paper stores in each SRAM array."""
    return jnp.asarray(_table_np(k), dtype=dtype)


def pow2_int(n: jax.Array) -> jax.Array:
    """Exact ``2^n`` for integer-valued f32 ``n`` via exponent-field construction.

    The CIM analogue is the paper's "bit-shift decided by n"; on TPU we build the
    float directly: ``bitcast((n + 127) << 23)``.  ``n`` is clamped to the normal
    range; n <= -127 flushes to 0 which is the correct softmax behaviour for
    heavily-masked logits.
    """
    n_i = jnp.clip(n, -127.0, 127.0).astype(jnp.int32)
    bits = jnp.where(n_i <= -127, 0, (n_i + 127) << 23)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def decompose(x: jax.Array, k: int = K):
    """Split ``x`` into (n, d, r_scaled) s.t. e^x = 2^n · 2^(d/k) · e^(r_scaled·ln2/k).

    r_scaled ∈ [0, 1) is the residual in units of ln2/k.
    """
    t = x.astype(jnp.float32) * LOG2E
    n = jnp.floor(t)
    f = t - n  # ∈ [0, 1)
    fk = f * k
    d = jnp.floor(fk)
    # Guard the d == k corner from f rounding up to 1.0.
    d = jnp.clip(d, 0.0, float(k - 1))
    r_scaled = fk - d
    return n, d.astype(jnp.int32), r_scaled


def residual_correction(r_scaled: jax.Array, k: int = K, order: int = 1) -> jax.Array:
    """e^r for r = r_scaled · ln2/k.  order 0 → 1 (paper err<0.54%); 1 → 1+r."""
    if order == 0:
        return jnp.ones_like(r_scaled)
    return 1.0 + r_scaled * (LN2 / k)


def lut_exp(x: jax.Array, *, k: int = K, order: int = 1,
            table: jax.Array | None = None) -> jax.Array:
    """LUT exponential, pure-jnp path (the oracle; used by the model code on CPU).

    The Pallas kernel in ``repro.kernels.lut_exp`` computes the same function with
    the table lookup performed as a one-hot MXU matmul.
    """
    dtype = x.dtype
    if table is None:
        table = make_table(k)
    xf = x.astype(jnp.float32)
    n, d, r = decompose(xf, k)
    looked = jnp.take(table.astype(jnp.float32), d, axis=0)
    out = pow2_int(n) * looked * residual_correction(r, k, order)
    # exp(-inf) and deep-underflow inputs → exactly 0 (masked attention positions).
    out = jnp.where(xf < UNDERFLOW_X, 0.0, out)
    return out.astype(dtype)


def lut_exp2(x: jax.Array, *, k: int = K, order: int = 1) -> jax.Array:
    """LUT ``2^x`` — handy for bases already in log2 domain."""
    return lut_exp(x * LN2, k=k, order=order)
