"""Fine-grained-pipelined ("streaming") attention — HASTILY §IV on TPU.

The paper streams one input *row* at a time through ``QKᵀ → softmax → ·V`` so the
``l×l`` logit matrix never exists (space O(l) instead of O(l²)).  The correctness
hinge is that softmax max/sum are *associatively combinable* — exactly the paper's
multi-core partial-max / partial-sum gather (§III-B2).

On TPU, one SRAM row-vector becomes one MXU tile: we stream over **blocks** of the
KV sequence, carrying the running ``(max m, denominator l, weighted accumulator)``
online-softmax state.  A custom VJP re-streams the blocks in the backward pass
(saving only ``out`` and the per-row logsumexp), so *training* is O(l) memory too —
the jaxpr-level guarantee ``no (Lq, Lkv) tensor exists`` is asserted in tests.

The exponent inside is pluggable: ``exp_mode="lut"`` uses the paper's 128-entry
LUT decomposition; ``exp_mode="exact"`` is the PUMA/GPU-style baseline.  The Pallas
TPU kernel version lives in ``repro.kernels.streaming_attention``; this module is
the pure-jnp implementation used on CPU and for lowering in the dry-run.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp
from repro.core.lut_softmax import NEG_INF, softcap
from repro.parallel.ctx import maybe_shard

_EXP_FNS = {
    "lut": lambda x: lut_exp(x, order=1),
    "lut0": lambda x: lut_exp(x, order=0),
    "exact": jnp.exp,
}


class AttnConfig(NamedTuple):
    """Static attention configuration (hashable → usable as nondiff argnum)."""
    scale: float
    causal: bool = False
    window: Optional[int] = None       # sliding-window size (local attention)
    cap: Optional[float] = None        # gemma-2 logit softcap
    block_k: int = 512                 # KV streaming block (the "pipeline vector")
    exp_mode: str = "lut"              # lut | lut0 | exact


def _split_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, Hq, Lq, D) → (B, Hkv, G, Lq, D) grouped-query layout."""
    b, hq, lq, d = q.shape
    assert hq % n_kv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {n_kv}"
    return q.reshape(b, n_kv, hq // n_kv, lq, d)


def _block_mask(cfg: AttnConfig, q_pos: jax.Array, kv_idx: jax.Array,
                kv_pos: jax.Array, kv_len: jax.Array) -> jax.Array:
    """Boolean (Bp, 1, 1, Lq, bk) mask for one KV block.

    ``kv_idx`` (bk,) is the *structural* slot index (bounds the valid cache
    prefix via kv_len); ``kv_pos`` (Bp, bk) is the *absolute position* of each
    slot — they differ for ring-buffer sliding-window caches, where slot
    positions wrap (negative = never written).  Bp is 1 (synthetic positions)
    or B (explicit per-batch ring positions).
    """
    qp = q_pos[None, :, None]              # (1, Lq, 1)
    kp = kv_pos[:, None, :]                # (Bp, 1, bk)
    m = (kp >= 0) & (kv_idx[None, None, :] < kv_len)
    if cfg.causal:
        m &= kp <= qp
    if cfg.window is not None:
        m &= (qp - kp) < cfg.window
    return m[:, None, None]                # (Bp, 1, 1, Lq, bk)


def _logits(cfg: AttnConfig, q: jax.Array, k_blk: jax.Array):
    """Raw and soft-capped logits for one block.  q:(B,Hkv,G,Lq,D) k:(B,Hkv,bk,D)."""
    s_raw = jnp.einsum("bhgqd,bhkd->bhgqk", q,
                       k_blk.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * cfg.scale
    return s_raw, softcap(s_raw, cfg.cap)


def _blocked_kv(x: jax.Array, block: int):
    """(B, H, L, D) → (nb, B, H, block, D), padding L up to a block multiple."""
    b, h, l, d = x.shape
    nb = -(-l // block)
    pad = nb * block - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return jnp.moveaxis(x.reshape(b, h, nb, block, d), 2, 0)


def _blocked_pos(p: jax.Array, block: int):
    """(Bp, L) int32 → (nb, Bp, block), padding with -1 (= invalid slot)."""
    bp, l = p.shape
    nb = -(-l // block)
    pad = nb * block - l
    if pad:
        p = jnp.pad(p, ((0, 0), (0, pad)), constant_values=-1)
    return jnp.moveaxis(p.reshape(bp, nb, block), 1, 0)


def _attention_fwd_scan(cfg: AttnConfig, q, kb, vb, pb, q_pos, kv_len):
    """Online-softmax forward.  Returns (out, logsumexp)."""
    exp_fn = _EXP_FNS[cfg.exp_mode]
    b, hkv, g, lq, d = q.shape
    nb, _, _, bk, dv = vb.shape

    def body(carry, blk):
        m, l, acc = carry
        j, k_blk, v_blk, p_blk = blk
        kv_idx = j * bk + jnp.arange(bk)
        _, s = _logits(cfg, q, k_blk)
        mask = _block_mask(cfg, q_pos, kv_idx, p_blk, kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = exp_fn(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = exp_fn(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, lq), jnp.float32),
            jnp.zeros((b, hkv, g, lq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nb), kb, vb, pb))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _streaming_attention(cfg: AttnConfig, q, k, v, kv_pos, q_pos, kv_len):
    out, _ = _attention_fwd_scan(cfg, q, _blocked_kv(k, cfg.block_k),
                                 _blocked_kv(v, cfg.block_k),
                                 _blocked_pos(kv_pos, cfg.block_k),
                                 q_pos, kv_len)
    return out


def _fwd(cfg, q, k, v, kv_pos, q_pos, kv_len):
    kb = _blocked_kv(k, cfg.block_k)
    vb = _blocked_kv(v, cfg.block_k)
    pb = _blocked_pos(kv_pos, cfg.block_k)
    out, lse = _attention_fwd_scan(cfg, q, kb, vb, pb, q_pos, kv_len)
    return out, (q, k, v, kv_pos, q_pos, kv_len, out, lse)


def _bwd(cfg, res, dout):
    """Flash-style backward: re-stream KV blocks, saving no l×l tensor."""
    q, k, v, kv_pos, q_pos, kv_len, out, lse = res
    exp_fn = _EXP_FNS[cfg.exp_mode]
    kb = _blocked_kv(k, cfg.block_k)
    vb = _blocked_kv(v, cfg.block_k)
    pb = _blocked_pos(kv_pos, cfg.block_k)
    nb, b, hkv, bk, d = kb.shape
    lkv = k.shape[2]
    dout = dout.astype(jnp.float32)
    delta = jnp.sum(dout * out, axis=-1)  # (B,Hkv,G,Lq)

    def body(dq_acc, blk):
        j, k_blk, v_blk, p_blk = blk
        kv_idx = j * bk + jnp.arange(bk)
        s_raw, s_c = _logits(cfg, q, k_blk)
        mask = _block_mask(cfg, q_pos, kv_idx, p_blk, kv_len)
        p = exp_fn(jnp.where(mask, s_c, NEG_INF) - lse[..., None])
        p = jnp.where(mask, p, 0.0)
        dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", p, dout,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dout, v_blk,
                        preferred_element_type=jnp.float32)
        ds_c = p * (dp - delta[..., None])
        if cfg.cap is not None:
            ds_raw = ds_c * (1.0 - (s_c / cfg.cap) ** 2)
        else:
            ds_raw = ds_c
        ds_raw = ds_raw * cfg.scale  # d(q·k)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds_raw, k_blk,
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds_raw, q,
                            preferred_element_type=jnp.float32)
        return dq_acc, (dk_blk, dv_blk)

    dq, (dkb, dvb) = jax.lax.scan(
        body, jnp.zeros(q.shape, jnp.float32), (jnp.arange(nb), kb, vb, pb))

    def unblock(xb):
        x = jnp.moveaxis(xb, 0, 2).reshape(b, hkv, nb * bk, -1)
        return x[:, :, :lkv]

    return (dq.astype(q.dtype), unblock(dkb).astype(k.dtype),
            unblock(dvb).astype(v.dtype), None, None, None)


_streaming_attention.defvjp(_fwd, _bwd)


def streaming_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: Optional[float] = None,
                        causal: bool = False,
                        window: Optional[int] = None,
                        cap: Optional[float] = None,
                        block_k: int = 512,
                        exp_mode: str = "lut",
                        q_offset: jax.Array | int = 0,
                        kv_len: Optional[jax.Array | int] = None,
                        kv_pos: Optional[jax.Array] = None) -> jax.Array:
    """HASTILY streaming attention.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D) with Hq % Hkv == 0 (GQA).
    ``q_offset`` is the absolute position of q[…, 0, :] (decode: cache length);
    ``kv_len`` masks a partially-filled KV cache.  ``kv_pos`` (B, Lkv) gives
    explicit absolute positions per KV slot (ring-buffer sliding-window
    caches; -1 = never written).  Returns (B, Hq, Lq, D).
    """
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, max(lkv, 1))
    cfg = AttnConfig(scale=float(scale), causal=causal, window=window, cap=cap,
                     block_k=int(block_k), exp_mode=exp_mode)
    qg = _split_heads(q.astype(jnp.float32), hkv)
    # Sequence-parallel queries: the (…, Lq, block_k) score tiles are the
    # dominant attention transient; sharding Lq over the model axis divides
    # them mesh-wide while KV stays replicated (ring-attention-lite — the
    # full KV ring is core/ring_attention.py).  No-op without an active mesh.
    if lq > 1:
        qg = maybe_shard(qg, ("dp", None, None, "sp", None))
    q_pos = (jnp.asarray(q_offset, jnp.int32) + jnp.arange(lq, dtype=jnp.int32))
    kv_len = jnp.asarray(lkv if kv_len is None else kv_len, jnp.int32)
    if kv_pos is None:
        kv_pos = jnp.arange(lkv, dtype=jnp.int32)[None, :]
    # K/V stay in their storage dtype — each block is upcast inside the
    # scan body; a wholesale f32 cast would materialise a 2× copy of the
    # entire KV cache (ruinous for 32k-decode).
    out = _streaming_attention(cfg, qg, k, v,
                               kv_pos.astype(jnp.int32), q_pos, kv_len)
    return out.reshape(b, hq, lq, d).astype(q.dtype)


def streaming_attention_quantized(q: jax.Array, kq: jax.Array, vq: jax.Array,
                                  k_scale: jax.Array, v_scale: jax.Array, *,
                                  scale: Optional[float] = None,
                                  causal: bool = True,
                                  window: Optional[int] = None,
                                  cap: Optional[float] = None,
                                  block_k: int = 512,
                                  exp_mode: str = "lut",
                                  q_offset: jax.Array | int = 0,
                                  kv_len: Optional[jax.Array | int] = None,
                                  kv_pos: Optional[jax.Array] = None
                                  ) -> jax.Array:
    """Streaming attention over an INT8-quantised KV cache (inference only).

    kq/vq: (B, Hkv, Lkv, D) int8; k_scale/v_scale: (B, Hkv, Lkv) f32
    per-row scales.  Each KV block is dequantised *inside* the scan body —
    O(block) f32 transient, while the resident cache stays int8 (2× smaller
    than bf16, 4× smaller than f32; the paper's INT8 theme applied to the
    serving-memory bottleneck).  Forward-only: decode/prefill paths don't
    differentiate through the cache.
    """
    b, hq, lq, d = q.shape
    hkv, lkv = kq.shape[1], kq.shape[2]
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, max(lkv, 1))
    cfg = AttnConfig(scale=float(scale), causal=causal, window=window,
                     cap=cap, block_k=int(block_k), exp_mode=exp_mode)
    qg = _split_heads(q.astype(jnp.float32), hkv)
    q_pos = (jnp.asarray(q_offset, jnp.int32)
             + jnp.arange(lq, dtype=jnp.int32))
    kv_len = jnp.asarray(lkv if kv_len is None else kv_len, jnp.int32)
    if kv_pos is None:
        kv_pos = jnp.arange(lkv, dtype=jnp.int32)[None, :]

    if lq == 1:
        # Single-token decode: logits are O(L) — skip the block scan (it
        # costs a collective-permute per block on sharded caches; §Perf).
        qg2 = qg
        kf = kq.astype(jnp.float32) * k_scale[..., None]
        vf = vq.astype(jnp.float32) * v_scale[..., None]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg2, kf,
                       preferred_element_type=jnp.float32) * cfg.scale
        s = softcap(s, cfg.cap)
        kv_idx = jnp.arange(lkv, dtype=jnp.int32)
        mask = _block_mask(cfg, q_pos, kv_idx, kv_pos.astype(jnp.int32),
                           kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(m <= NEG_INF, 0.0, m)
        p = jnp.where(mask, _EXP_FNS[cfg.exp_mode](s - m), 0.0)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf,
                         preferred_element_type=jnp.float32)
        denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        return (out / denom).reshape(b, hq, lq, d).astype(q.dtype)

    # Blocks carry int8 values + per-row scales through the scan and are
    # dequantised inside the body — O(block) f32 transient, int8 resident.
    kb = _blocked_kv(kq, cfg.block_k)
    vb = _blocked_kv(vq, cfg.block_k)
    ksb = _blocked_kv(k_scale[..., None], cfg.block_k)
    vsb = _blocked_kv(v_scale[..., None], cfg.block_k)
    pb = _blocked_pos(kv_pos.astype(jnp.int32), cfg.block_k)
    exp_fn = _EXP_FNS[cfg.exp_mode]
    nb, _, _, bk, _ = vb.shape
    g = hq // hkv

    def body(carry, blk):
        m, l, acc = carry
        j, k_i8, v_i8, ks, vs, p_blk = blk
        k_blk = k_i8.astype(jnp.float32) * ks
        v_blk = v_i8.astype(jnp.float32) * vs
        kv_idx = j * bk + jnp.arange(bk)
        _, s = _logits(cfg, qg, k_blk)
        mask = _block_mask(cfg, q_pos, kv_idx, p_blk, kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, exp_fn(s - m_new[..., None]), 0.0)
        alpha = exp_fn(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, lq), jnp.float32),
            jnp.zeros((b, hkv, g, lq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nb), kb, vb, ksb, vsb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, d).astype(q.dtype)


def quantize_kv_rows(x: jax.Array) -> tuple:
    """(B, H, L, D) float → (int8 values, (B, H, L) f32 per-row scales)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -128, 127).astype(jnp.int8)
    return q, s


def naive_attention(q, k, v, *, scale=None, causal=False, window=None, cap=None,
                    exp_mode: str = "exact", q_offset=0, kv_len=None,
                    kv_pos: Optional[jax.Array] = None):
    """Materialised-logits baseline (the "PUMA" dataflow): O(l²) memory.

    Used as the correctness oracle and as the paper-baseline arm of every A/B.
    """
    from repro.core.lut_softmax import lut_softmax  # local to avoid cycle
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5
    qg = _split_heads(q.astype(jnp.float32), hkv)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.asarray(q_offset, jnp.int32) + jnp.arange(lq, dtype=jnp.int32)
    kv_idx = jnp.arange(lkv, dtype=jnp.int32)
    if kv_pos is None:
        kv_pos = kv_idx[None, :]
    kp = kv_pos[:, None, :]                                       # (Bp, 1, Lkv)
    qp = q_pos[None, :, None]                                     # (1, Lq, 1)
    mask = (kp >= 0) & (kv_idx[None, None, :]
                        < jnp.asarray(lkv if kv_len is None else kv_len))
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & ((qp - kp) < window)
    exp_fn = _EXP_FNS[exp_mode]
    p = lut_softmax(s, where=mask[:, None, None], exp_fn=exp_fn, cap=cap)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, lq, d).astype(q.dtype)
